"""ISSUE-2 acceptance surface: the vectorized sweep engine reproduces
the seed's Python-loop sweeps, and the lax.switch heterogeneous train
step is bit-identical to the PR-1 unrolled path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommPolicy, build_stage_bank
from repro.configs.base import TrainConfig
from repro.configs.paper_linreg import FIG2_LEFT
from repro.core import regression as R
from repro.core.api import init_train_state, make_triggered_train_step
from repro.optim import optimizers as opt_lib

STEPS, TRIALS = 10, 64


@pytest.fixture(scope="module")
def problem():
    return R.make_problem(FIG2_LEFT, jax.random.key(0))


# ----------------------------------------------------------------------
# sweep engine vs the seed's per-λ Python loop
# ----------------------------------------------------------------------

def _seed_lambda_sweep(problem, key, steps, lams, num_trials, mode):
    """The seed implementation of lambda_sweep, kept as the reference."""
    out_J, out_comm, out_any = [], [], []
    for lam in lams:
        res = R.run_many(problem, key, steps, num_trials, mode=mode,
                         lam=float(lam))
        out_J.append(jnp.mean(res.J_traj[:, -1]))
        out_comm.append(jnp.mean(jnp.sum(res.alphas, axis=(1, 2))))
        out_any.append(jnp.mean(jnp.sum(jnp.max(res.alphas, axis=2), axis=1)))
    return jnp.stack(out_J), jnp.stack(out_comm), jnp.stack(out_any)


def _seed_mu_sweep(problem, key, steps, mus, num_trials):
    out_J, out_comm = [], []
    for mu in mus:
        res = R.run_many(problem, key, steps, num_trials, mode="grad_norm",
                         mu=float(mu))
        out_J.append(jnp.mean(res.J_traj[:, -1]))
        out_comm.append(jnp.mean(jnp.sum(res.alphas, axis=(1, 2))))
    return jnp.stack(out_J), jnp.stack(out_comm)


# Golden values minted by running the SEED-commit (pre-rewrite, Python
# `if mode ==` triggers) lambda_sweep/mu_sweep on FIG2_LEFT with
# key(0)/key(1), steps=10, trials=64 — pins the lax.switch rewrite to
# the original numerics, not merely to itself.
_SEED_LAMS = [0.0, 0.1, 0.4, 1.6, 6.4]
_SEED_LAMBDA_GOLD = (
    [2.17334270, 2.02645516, 1.92962575, 2.55836558, 5.31802416],  # J
    [20.0, 18.703125, 15.1875, 8.96875, 3.609375],                 # comm
    [10.0, 9.90625, 9.015625, 6.21875, 2.9375],                    # any_tx
)
_SEED_MUS = [0.0, 1.0, 10.0, 100.0]
_SEED_MU_GOLD = (
    [2.17334270, 2.04162741, 2.17509151, 6.38211632],              # J
    [20.0, 18.8125, 11.4375, 2.859375],                            # comm
)


def test_lambda_sweep_matches_seed_golden_values(problem):
    got = R.lambda_sweep(problem, jax.random.key(1), STEPS, _SEED_LAMS, 64)
    for g, w in zip(got, _SEED_LAMBDA_GOLD):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_mu_sweep_matches_seed_golden_values(problem):
    got = R.mu_sweep(problem, jax.random.key(1), STEPS, _SEED_MUS, 64)
    for g, w in zip(got, _SEED_MU_GOLD):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["gain_estimated", "gain_exact"])
def test_lambda_sweep_matches_seed_loop(problem, mode):
    """One jitted sweep() == the seed's run_many-per-λ loop to 1e-5."""
    key = jax.random.key(1)
    lams = [0.0, 0.1, 0.4, 1.6, 6.4]
    want = _seed_lambda_sweep(problem, key, STEPS, lams, TRIALS, mode)
    got = R.lambda_sweep(problem, key, STEPS, lams, TRIALS, mode=mode)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_mu_sweep_matches_seed_loop(problem):
    key = jax.random.key(2)
    mus = [0.0, 1.0, 10.0, 100.0]
    want = _seed_mu_sweep(problem, key, STEPS, mus, TRIALS)
    got = R.mu_sweep(problem, key, STEPS, mus, TRIALS)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_sweep_single_point_lane_equals_run_many(problem):
    """A sweep lane carries exactly a run_many trajectory (same keys)."""
    key = jax.random.key(3)
    rm = R.run_many(problem, key, STEPS, 8, mode="grad_norm", mu=3.0)
    sw = R.sweep(problem, key, STEPS, R.mu_grid([3.0]), 8)
    np.testing.assert_array_equal(np.asarray(rm.J_traj),
                                  np.asarray(sw.J_traj[0]))
    np.testing.assert_array_equal(np.asarray(rm.alphas),
                                  np.asarray(sw.alphas[0]))


def test_mixed_mode_grid_in_one_sweep(problem):
    """Modes, λs, μs and decay ids all vary inside ONE vmapped grid."""
    key = jax.random.key(4)
    grid = R.grid_concat(
        R.lambda_grid([0.2], mode="gain_exact", lam_decay="geometric"),
        R.mu_grid([5.0]),
        R.grid_from_specs(["always", "never"]),
    )
    res = R.sweep(problem, key, STEPS, grid, 8)
    assert res.J_traj.shape == (4, 8, STEPS + 1)
    ref = R.run_many(problem, key, STEPS, 8, mode="gain_exact", lam=0.2,
                     lam_decay="geometric")
    np.testing.assert_array_equal(np.asarray(ref.alphas),
                                  np.asarray(res.alphas[0]))
    # always transmits everywhere, never nowhere
    assert float(jnp.sum(res.alphas[2])) == 8 * STEPS * problem.num_agents
    assert float(jnp.sum(res.alphas[3])) == 0.0


def test_knob_vocabulary_errors():
    with pytest.raises(ValueError, match="unknown mode"):
        R.make_knobs(mode="warp")
    with pytest.raises(ValueError, match="unknown lam_decay"):
        R.make_knobs(lam_decay="sometimes")
    with pytest.raises(ValueError, match="empty sweep grid"):
        R.grid_from_points([])


# ----------------------------------------------------------------------
# lax.switch heterogeneous dispatch vs the PR-1 unrolled loop
# ----------------------------------------------------------------------

N_FEATURES = 4


def linreg_loss(params, batch):
    xs, ys = batch
    r = xs @ params["w"] - ys
    return 0.5 * jnp.mean(r * r)


def _batch(key, A, n=16):
    kx, kn = jax.random.split(key)
    xs = jax.random.normal(kx, (A, n, N_FEATURES))
    w_star = jnp.arange(1.0, N_FEATURES + 1)
    ys = jnp.einsum("anj,j->an", xs, w_star) + 0.05 * jax.random.normal(
        kn, (A, n)
    )
    return xs, ys


def _train(cfg, dispatch, steps=12):
    opt = opt_lib.from_config(cfg)
    step_fn = jax.jit(make_triggered_train_step(
        linreg_loss, opt, cfg, hetero_dispatch=dispatch
    ))
    state = init_train_state({"w": jnp.zeros(N_FEATURES)}, opt, cfg)
    hist = []
    for s in range(steps):
        state, m = step_fn(state, _batch(jax.random.key(s), cfg.num_agents))
        hist.append({k: np.asarray(v) for k, v in m.items()})
    return state, hist


# (dispatch-path equivalence at m=4 — incl. the adamw variant — now
# lives in tests/test_dispatch_differential.py, the one parametrized
# harness over mixes × wire models × controllers)


def test_switch_dispatch_scales_to_m16_with_3_banks():
    """m=16 agents over 3 distinct policies: the bank dedupes to 3
    branches and the step trains."""
    comm = tuple(["always"] * 6
                 + ["gain_lookahead(lam=0.01)|int8+ef"] * 5
                 + ["grad_norm(mu=0.5)|randk(0.5)"] * 5)
    cfg = TrainConfig(lr=0.1, optimizer="sgd", num_agents=16, comm=comm)
    state, hist = _train(cfg, "switch", steps=8)
    assert float(hist[-1]["loss"]) < float(hist[0]["loss"])
    assert all(0.0 <= float(h["comm_rate"]) <= 1.0 for h in hist)


def test_invalid_dispatch_rejected():
    """ISSUE-5 satellite: an unknown mode fails up front with an error
    that lists every valid mode (the same DISPATCH_MODES vocabulary
    benchmarks/run.py --dispatch validates against)."""
    from repro.core.api import DISPATCH_MODES

    cfg = TrainConfig(lr=0.1, optimizer="sgd", num_agents=2,
                      comm=("always", "never"))
    opt = opt_lib.from_config(cfg)
    with pytest.raises(ValueError, match="hetero_dispatch") as err:
        make_triggered_train_step(linreg_loss, opt, cfg,
                                  hetero_dispatch="sideways")
    assert DISPATCH_MODES == ("hybrid", "switch", "unroll")
    for mode in DISPATCH_MODES:
        assert mode in str(err.value)


# ----------------------------------------------------------------------
# stage bank
# ----------------------------------------------------------------------

def test_stage_bank_dedupes_policies():
    pols = CommPolicy.parse(
        "always ; gain_lookahead(lam=0.1)|int8+ef ; always ; "
        "gain_lookahead(lam=0.1)|int8+ef ; never"
    )
    bank = build_stage_bank(pols, loss_fn=linreg_loss, probe_eps=0.1)
    assert len(bank.policies) == 3
    assert bank.agent_index == (0, 1, 0, 1, 2)
    assert bank.needs_ef
    assert len(bank.agent_chains()) == 5
    assert len(bank.stages(True)) == 3


def test_stage_bank_uniform_signature_smoke():
    """Every stage answers the uniform (params, grad, batch, loss, step,
    ef_mem[, ctrl[, scale]]) call with a uniform (alpha, gain, sent,
    new_mem, new_ctrl) tuple — and without a controller slot, new_ctrl
    is None for every branch (stable pytree carry)."""
    pols = CommPolicy.parse("always|int8 ; grad_norm(mu=0.0)")
    bank = build_stage_bank(pols, loss_fn=linreg_loss, probe_eps=0.1)
    assert not bank.needs_ctrl
    params = {"w": jnp.zeros(N_FEATURES)}
    xs, ys = _batch(jax.random.key(0), 2)
    ab = (xs[0], ys[0])
    g = jax.grad(linreg_loss)(params, ab)
    for stage in bank.stages(False):
        alpha, gain, sent, new_mem, new_ctrl = stage(
            params, g, ab, linreg_loss(params, ab), jnp.int32(0), None
        )
        assert alpha.shape == () and gain.shape == ()
        assert jax.tree_util.tree_structure(sent) == \
            jax.tree_util.tree_structure(g)
        assert new_mem is None
        assert new_ctrl is None
