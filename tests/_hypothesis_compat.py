"""Optional-hypothesis shim: property tests skip cleanly when the
`hypothesis` package is absent (bare CPU boxes), example-based tests in
the same module still run.

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CPU boxes
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategiesStub:
        """Accepts any strategy construction at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategiesStub()
