"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device
(only launch/dryrun.py forces the 512-device placeholder topology)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def tiny_cfg(arch_id: str, **overrides):
    cfg = reduced(get_config(arch_id))
    return cfg.replace(**overrides) if overrides else cfg


def tiny_batch(cfg, key, batch=2, seq=32):
    """Concrete batch matching models.input_specs structure (no agent axis)."""
    kt, ke = jax.random.split(key)
    toks = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab_size)
    out = {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
    }
    if cfg.arch_type == "vlm":
        out["patch_embeds"] = 0.02 * jax.random.normal(
            ke, (batch, cfg.num_patches, cfg.d_model), jnp.float32
        )
    if cfg.arch_type == "audio":
        dec = min(seq, 24)
        out = {
            "frame_embeds": 0.02 * jax.random.normal(
                ke, (batch, seq, cfg.d_model), jnp.float32
            ),
            "tokens": toks[:, :dec].astype(jnp.int32),
            "labels": toks[:, 1 : dec + 1].astype(jnp.int32),
        }
    return out
