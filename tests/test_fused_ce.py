"""fused_ce Pallas kernel vs jnp oracle: shape/dtype sweep + model-path
equivalence (assignment per-kernel requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ce import ops as ce_ops
from repro.kernels.fused_ce import ref as ce_ref


@pytest.mark.parametrize(
    "T,D,V", [(128, 32, 257), (200, 64, 1000), (64, 16, 7), (130, 48, 4096)]
)
def test_fused_ce_matches_ref(T, D, V, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = 0.5 * jax.random.normal(k1, (T, D))
    tbl = 0.1 * jax.random.normal(k2, (V, D))
    lab = jax.random.randint(k3, (T,), 0, V)
    got = float(ce_ops.fused_ce(x, tbl, lab, bt=64, bv=128))
    want = float(jnp.mean(ce_ref.fused_ce_ref(x, tbl, lab)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)])
def test_fused_ce_dtypes(dtype, tol, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    x = (0.5 * jax.random.normal(k1, (128, 32))).astype(dtype)
    tbl = (0.1 * jax.random.normal(k2, (500, 32))).astype(dtype)
    lab = jax.random.randint(k3, (128,), 0, 500)
    got = float(ce_ops.fused_ce(x, tbl, lab, bt=64, bv=128))
    want = float(jnp.mean(ce_ref.fused_ce_ref(x, tbl, lab)))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fused_ce_batched_layout_matches_model_loss(rng):
    """Kernel ≡ the model's chunked-CE loss on a real reduced arch
    (forward values; the jnp path remains the differentiable one)."""
    from conftest import tiny_batch, tiny_cfg
    from repro.models import build
    from repro.models.transformer import forward_hidden, output_table

    cfg = tiny_cfg("smollm-135m")
    model = build(cfg)
    params, _ = model.init(rng)
    batch = tiny_batch(cfg, jax.random.fold_in(rng, 1))
    want = float(model.loss_fn(params, batch))

    x, _, prefix = forward_hidden(cfg, params, batch)
    tbl = output_table(cfg, params)
    got = float(ce_ops.fused_ce(x, tbl, batch["labels"], bt=64, bv=128))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
