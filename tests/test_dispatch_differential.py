"""ISSUE-9 satellite: ONE parametrized differential harness for the
three heterogeneous dispatch paths (hybrid / switch / unroll) across
the full scenario matrix — tier mixes × wire models {ideal, bernoulli
loss, latency delay} × controller families {fixed-λ, budget-adaptive}.

Agreement policy (the suite-wide contract):

* **ideal wires** — hybrid, switch and unroll are BIT-identical in
  params, opt state, EF memory and every metric, with the one
  long-standing exception that ``mean_gain`` may sit one ULP off
  between the banked paths and the unrolled reference (probe-loss
  fusion context); hybrid vs switch has no fusion excuse and is held
  fully bitwise.
* **lossy / delayed wires** — parameters and float metrics agree to
  ~1 ULP (``rtol=1e-5``; the α·d·w application chain fuses differently
  per path) while the integer-valued channel realization — delivery
  indicators and staleness counters — stays EXACT across all three
  paths (they share the ``fold_in(fold_in(key, step), uid)`` draw).

This file subsumes the ad-hoc per-file equivalence tests that used to
live in test_sweep / test_frontier / test_adaptive / test_net (one
dispatch-agreement surface instead of seven hand-rolled ones).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.paper_linreg import (
    LinRegConfig,
    TIER_MIXES,
    TIERED_M64,
    TieredNetwork,
    _adaptive_tiers,
    _lossy,
    _tiers,
)
from repro.core import regression as R
from repro.core.api import StepOptions, init_train_state, \
    make_triggered_train_step
from repro.core.frontier import run_frontier
from repro.optim import optimizers as opt_lib

TOY4 = LinRegConfig(name="toy4", n=6, num_agents=4, samples_per_agent=8,
                    stepsize=0.1, steps=4)
TOY64 = LinRegConfig(name="toy64", n=6, num_agents=64,
                     samples_per_agent=8, stepsize=0.1, steps=2)

# wire models: one representative per channel family the matrix names.
# Seeds are explicit so every dispatch path draws the same realization.
CHANNELS = {
    "ideal": None,
    "bernoulli": "bernoulli(p=0.3,seed=3)",
    "delay": "delay(dist=geometric,lag=2.0,max_lag=4,discount=0.5,seed=5)",
}
CONTROLLERS = ("fixed", "adaptive")

# the four-tier template at 1 agent/tier — the m=4 differential core
# (unroll compiles per agent, so the exhaustive three-way matrix runs
# here; the m=64 fleets below pin the banked paths at scale)
M4_NETS = {
    "fixed": TieredNetwork("toy4_tiers", _tiers(1, 1, 1, 1, n=TOY4.n)),
    "adaptive": TieredNetwork("toy4_tiers_adaptive",
                              _adaptive_tiers(1, 1, 1, 1, n=TOY4.n)),
}


def _adaptive_mix(net):
    """The budget-adaptive counterpart of a fixed-λ tier mix: same
    four-tier layout and counts, controllers instead of hand-tuned λ."""
    return TieredNetwork(f"{net.name}_adaptive",
                         _adaptive_tiers(*(t.count for t in net.tiers),
                                         n=TOY64.n))


def _with_channel(net, channel):
    if CHANNELS[channel] is None:
        return net
    return _lossy(net, f"{net.name}_{channel}", CHANNELS[channel])


@pytest.fixture(scope="module")
def problem4():
    return R.make_problem(TOY4, jax.random.key(0))


@pytest.fixture(scope="module")
def problem64():
    return R.make_problem(TOY64, jax.random.key(42))


def linreg_loss(params, batch):
    xs, ys = batch
    r = xs @ params["w"] - ys
    return 0.5 * jnp.mean(r * r)


def _run(cfg, problem, dispatch, steps, n):
    opt = opt_lib.from_config(cfg)
    step = jax.jit(make_triggered_train_step(
        linreg_loss, opt, cfg,
        options=StepOptions(hetero_dispatch=dispatch, agent_metrics=True)))
    state = init_train_state({"w": jnp.zeros(n)}, opt, cfg)
    hist = []
    for i in range(steps):
        state, m = step(state, R.agent_batches(
            problem, jax.random.fold_in(jax.random.key(13), i)))
        hist.append({k: np.asarray(v) for k, v in m.items()})
    return state, hist


def _tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# metric keys that are the integer-valued channel/trigger realization —
# exact across paths under EVERY wire model
EXACT_KEYS = ("agent_tx", "agent_delivered", "agent_staleness",
              "num_tx", "any_tx")


def _assert_pair(got, ref, channel, tag):
    """Hold (state, hist) `got` to the agreement policy against `ref`."""
    (gs, gh), (rs, rh) = got, ref
    if channel == "ideal":
        assert _tree_equal(gs, rs), f"{tag}: state differs"
    else:
        for a, b in zip(jax.tree_util.tree_leaves(gs),
                        jax.tree_util.tree_leaves(rs)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=tag)
    for gm, rm in zip(gh, rh):
        assert set(gm) == set(rm)
        for k in rm:
            if channel == "ideal" and k != "mean_gain":
                np.testing.assert_array_equal(gm[k], rm[k],
                                              err_msg=f"{tag}:{k}")
            elif k in EXACT_KEYS:
                np.testing.assert_array_equal(gm[k], rm[k],
                                              err_msg=f"{tag}:{k}")
            else:
                np.testing.assert_allclose(gm[k], rm[k], rtol=1e-5,
                                           atol=1e-6,
                                           err_msg=f"{tag}:{k}")


# ----------------------------------------------------------------------
# m=4 differential core: full three-way matrix, unroll included
# ----------------------------------------------------------------------

@pytest.mark.parametrize("controller", CONTROLLERS)
@pytest.mark.parametrize("channel", tuple(CHANNELS))
def test_m4_three_way_matrix(problem4, channel, controller):
    net = _with_channel(M4_NETS[controller], channel)
    cfg = TrainConfig(lr=TOY4.stepsize, optimizer="sgd",
                      num_agents=net.num_agents,
                      comm=net.policies(lam_base=1.0))
    outs = {d: _run(cfg, problem4, d, steps=TOY4.steps, n=TOY4.n)
            for d in ("hybrid", "switch", "unroll")}
    for d in ("hybrid", "switch"):
        _assert_pair(outs[d], outs["unroll"], channel, f"{d}-vs-unroll")
    # banked paths pin each other bitwise regardless of wire model
    assert _tree_equal(outs["hybrid"][0], outs["switch"][0])
    for gm, rm in zip(outs["hybrid"][1], outs["switch"][1]):
        for k in rm:
            np.testing.assert_array_equal(gm[k], rm[k], err_msg=k)


def test_m4_three_way_under_adamw(problem4):
    """Stateful optimizer slots ride the same agreement contract (the
    opt-state tree is part of the compared state)."""
    net = _with_channel(M4_NETS["fixed"], "delay")
    cfg = TrainConfig(lr=0.05, optimizer="adamw",
                      num_agents=net.num_agents,
                      comm=net.policies(lam_base=1.0))
    outs = {d: _run(cfg, problem4, d, steps=TOY4.steps, n=TOY4.n)
            for d in ("hybrid", "switch", "unroll")}
    for d in ("hybrid", "switch"):
        _assert_pair(outs[d], outs["unroll"], "delay", f"{d}-vs-unroll")


# ----------------------------------------------------------------------
# m=64 fleets: hybrid ↔ switch across the whole matrix; the unrolled
# reference joins where it is load-bearing (ideal×fixed pins all four
# mixes against it; the delay×adaptive cell pins the newest machinery)
# ----------------------------------------------------------------------

M64_GRID = [(net, chan, ctrl)
            for net in TIER_MIXES
            for chan in CHANNELS
            for ctrl in CONTROLLERS]


def _m64_modes(net, channel, controller):
    if channel == "ideal" and controller == "fixed":
        return ("hybrid", "switch", "unroll")
    if net is TIERED_M64 and channel == "delay" and controller == "adaptive":
        return ("hybrid", "switch", "unroll")
    return ("hybrid", "switch")


@pytest.mark.parametrize(
    "net,channel,controller", M64_GRID,
    ids=[f"{n.name}-{c}-{t}" for n, c, t in M64_GRID])
def test_m64_fleet_matrix(problem64, net, channel, controller):
    base = net if controller == "fixed" else _adaptive_mix(net)
    mixed = _with_channel(base, channel)
    cfg = TrainConfig(lr=TOY64.stepsize, optimizer="sgd",
                      num_agents=mixed.num_agents,
                      comm=mixed.policies(lam_base=1.0))
    modes = _m64_modes(net, channel, controller)
    outs = {d: _run(cfg, problem64, d, steps=TOY64.steps, n=TOY64.n)
            for d in modes}
    if "unroll" in modes:
        for d in ("hybrid", "switch"):
            _assert_pair(outs[d], outs["unroll"], channel,
                         f"{d}-vs-unroll")
    # hybrid vs switch: fully bitwise, every wire model (same banked
    # branch programs, same fusion context)
    assert _tree_equal(outs["hybrid"][0], outs["switch"][0])
    for gm, rm in zip(outs["hybrid"][1], outs["switch"][1]):
        assert set(gm) == set(rm)
        for k in rm:
            np.testing.assert_array_equal(gm[k], rm[k], err_msg=k)


# ----------------------------------------------------------------------
# frontier grid vmap: the dispatch paths stay pinned under vmap too
# ----------------------------------------------------------------------

@pytest.mark.parametrize("channel", ("ideal", "delay"))
def test_m4_frontier_vmap_three_way(problem4, channel):
    """Every dispatch path agrees lane-for-lane under the grid vmap —
    the hybrid path's agent vmap composes with the grid vmap (vmap-of-
    vmap) and on this backend all three stay bit-identical, delay-line
    net state included."""
    net = _with_channel(M4_NETS["fixed"], channel)
    cfg = TrainConfig(lr=TOY4.stepsize, optimizer="sgd",
                      num_agents=net.num_agents,
                      comm=net.policies(lam_base=1.0))
    opt = opt_lib.from_config(cfg)
    kw = dict(scales=[0.0, 0.5, 1.0, 4.0], steps=TOY4.steps,
              batch_fn=lambda k: R.agent_batches(problem4, k),
              key=jax.random.key(5))
    outs = {d: run_frontier(linreg_loss, opt, cfg,
                            {"w": jnp.zeros(TOY4.n)},
                            hetero_dispatch=d, **kw)
            for d in ("hybrid", "switch", "unroll")}
    for d in ("hybrid", "switch"):
        assert _tree_equal(outs[d].state, outs["unroll"].state), d
        for k in outs[d].metrics:
            np.testing.assert_array_equal(
                np.asarray(outs[d].metrics[k]),
                np.asarray(outs["unroll"].metrics[k]), err_msg=f"{d}:{k}")


@pytest.mark.parametrize("channel", ("ideal", "delay"))
def test_m64_frontier_hybrid_matches_switch(problem64, channel):
    """A TIERED_M64 smoke-style frontier (grid vmap over the full
    64-agent fleet) matches between hybrid and switch within the
    suite's float tolerance — integer wire accounting exactly equal —
    with and without the latency wire."""
    mixed = _with_channel(TIERED_M64, channel)
    cfg = TrainConfig(lr=TOY64.stepsize, optimizer="sgd",
                      num_agents=mixed.num_agents,
                      comm=mixed.policies(lam_base=1.0))
    opt = opt_lib.from_config(cfg)
    kw = dict(scales=[0.0, 1.0, 4.0], steps=4,
              batch_fn=lambda k: R.agent_batches(problem64, k),
              key=jax.random.key(17))
    hy = run_frontier(linreg_loss, opt, cfg, {"w": jnp.zeros(TOY64.n)},
                      hetero_dispatch="hybrid", **kw)
    sw = run_frontier(linreg_loss, opt, cfg, {"w": jnp.zeros(TOY64.n)},
                      hetero_dispatch="switch", **kw)
    for a, b in zip(jax.tree_util.tree_leaves(hy.state),
                    jax.tree_util.tree_leaves(sw.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for k in ("num_tx", "wire_bytes", "any_tx", "agent_tx"):
        np.testing.assert_array_equal(np.asarray(hy.metrics[k]),
                                      np.asarray(sw.metrics[k]), err_msg=k)
    for k in ("loss", "mean_gain", "agent_bytes"):
        np.testing.assert_allclose(np.asarray(hy.metrics[k]),
                                   np.asarray(sw.metrics[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
